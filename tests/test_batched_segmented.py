"""Batched + segmented multisplit acceptance (ISSUE 2): bitwise equivalence
with independent flat calls on every backend, single-launch execution, and
the rewired consumers (segmented_radix_sort, multisplit_all_shards, MoE
segmented routing)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import plan as msplan
from repro.core.identifiers import delta_buckets
from repro.core.multisplit import (
    batched_multisplit,
    multisplit,
    multisplit_ref,
    segmented_multisplit,
)
from repro.core.sort import radix_sort, segmented_radix_sort
from repro.core.distributed import multisplit_all_shards
from repro.models import moe

BACKENDS = ["reference", "vmap", "pallas-interpret"]


def _keys(n, seed=0, hi=2**30):
    return jnp.asarray(np.random.RandomState(seed).randint(0, hi, size=n, dtype=np.uint32))


# ---------------------------------------------------------------------------
# Acceptance: bitwise identity with independent calls, every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", ["dms", "wms", "bms"])
def test_segmented_bitwise_identical_to_independent_calls(backend, method):
    """THE acceptance criterion: segmented multisplit over b segments ==
    b independent multisplit calls, bitwise, on every backend."""
    m = 13
    bf = delta_buckets(m, 2**30)
    n = 1400
    keys = _keys(n, seed=3)
    vals = jnp.arange(n, dtype=jnp.int32)
    starts = [0, 211, 211, 650, 1399]            # ragged + empty + size-1 tail
    ends = starts[1:] + [n]
    out = segmented_multisplit(keys, bf, starts, vals, method=method, tile=256, backend=backend)
    for i, (a, e) in enumerate(zip(starts, ends)):
        ind = multisplit(keys[a:e], bf, vals[a:e], method=method, tile=256, backend=backend)
        np.testing.assert_array_equal(np.asarray(out.keys[a:e]), np.asarray(ind.keys))
        np.testing.assert_array_equal(np.asarray(out.values[a:e]), np.asarray(ind.values))
        np.testing.assert_array_equal(
            np.asarray(out.bucket_counts[i]), np.asarray(ind.bucket_counts)
        )
        np.testing.assert_array_equal(
            np.asarray(out.bucket_starts[i]), np.asarray(ind.bucket_starts)
        )
        np.testing.assert_array_equal(
            np.asarray(out.permutation[a:e]), np.asarray(ind.permutation)
        )


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", ["dms", "wms", "bms"])
def test_batched_bitwise_identical_to_independent_calls(backend, method):
    m, b, n = 13, 6, 700
    bf = delta_buckets(m, 2**30)
    keys = _keys(b * n, seed=5).reshape(b, n)
    vals = jnp.asarray(
        np.random.RandomState(6).randint(0, 2**20, (b, n), dtype=np.int32)
    )
    out = batched_multisplit(keys, bf, vals, method=method, tile=256, backend=backend)
    for i in range(b):
        ind = multisplit(keys[i], bf, vals[i], method=method, tile=256, backend=backend)
        np.testing.assert_array_equal(np.asarray(out.keys[i]), np.asarray(ind.keys))
        np.testing.assert_array_equal(np.asarray(out.values[i]), np.asarray(ind.values))
        np.testing.assert_array_equal(
            np.asarray(out.bucket_counts[i]), np.asarray(ind.bucket_counts)
        )
        np.testing.assert_array_equal(
            np.asarray(out.permutation[i]), np.asarray(ind.permutation)
        )


# ---------------------------------------------------------------------------
# Single launch: the whole batch / all segments go through ONE kernel-grid
# entry-point invocation, not one per row/segment
# ---------------------------------------------------------------------------

def _count_calls(monkeypatch, module, name):
    calls = []
    orig = getattr(module, name)

    def spy(*a, **k):
        calls.append(name)
        return orig(*a, **k)

    monkeypatch.setattr(module, name, spy)
    return calls


def test_batched_pallas_is_one_grid_launch(monkeypatch):
    from repro.kernels import ops as kops

    # delta specs are label-fused since PR-4: count the spec entry points.
    # m=4 keeps the dense one-hot family (PACKED_MIN_BUCKETS=8 since ISSUE 6)
    # so the dense entry points spied on below are the ones dispatched.
    pre = _count_calls(monkeypatch, kops, "spec_tile_histograms")
    post = _count_calls(monkeypatch, kops, "spec_fused_postscan_reorder")
    b, n = 8, 512
    keys = _keys(b * n, seed=7).reshape(b, n)
    bf = delta_buckets(4, 2**30)
    out = batched_multisplit(keys, bf, tile=256, backend="pallas-interpret")
    assert len(pre) == 1 and len(post) == 1       # 8 rows, ONE launch each stage
    ref = multisplit_ref(keys.reshape(-1)[:n], bf)
    np.testing.assert_array_equal(np.asarray(out.keys[0]), np.asarray(ref.keys))


def test_segmented_pallas_is_one_grid_launch(monkeypatch):
    from repro.kernels import ops as kops

    # the combined seg width (5 segments x 4 buckets = 20 >= 8) selects the
    # PACKED family since ISSUE 6, whose generic kernels cover flat AND
    # segmented in the same entry points
    pre = _count_calls(monkeypatch, kops, "packed_tile_histograms")
    post = _count_calls(monkeypatch, kops, "packed_fused_postscan_reorder")
    keys = _keys(1000, seed=8)
    bf = delta_buckets(4, 2**30)
    segmented_multisplit(keys, bf, [0, 100, 400, 400, 900], tile=256, backend="pallas-interpret")
    assert len(pre) == 1 and len(post) == 1       # 5 ragged segments, ONE launch


def test_segmented_radix_sort_pallas_never_materializes_labels(monkeypatch):
    """The fused-digit guarantee extends to the segmented path: no
    BucketIdentifier is ever evaluated host-side."""
    from repro.core import identifiers

    calls = []
    orig = identifiers.BucketIdentifier.__call__

    def spy(self, keys):
        calls.append(self.name)
        return orig(self, keys)

    monkeypatch.setattr(identifiers.BucketIdentifier, "__call__", spy)
    keys = _keys(900, seed=9, hi=2**32)
    vals = jnp.arange(900, dtype=jnp.int32)
    starts = [0, 300, 300, 500]
    ks, vs = segmented_radix_sort(
        keys, starts, vals, radix_bits=4, use_pallas=True, tile=256
    )
    assert calls == [], f"host-side label materialization via {calls}"
    ends = starts[1:] + [900]
    for a, e in zip(starts, ends):
        order = np.argsort(np.asarray(keys[a:e]), kind="stable")
        np.testing.assert_array_equal(np.asarray(ks[a:e]), np.asarray(keys[a:e])[order])
        np.testing.assert_array_equal(np.asarray(vs[a:e]), np.asarray(vals[a:e])[order])


# ---------------------------------------------------------------------------
# Consumers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["vmap", "pallas-interpret"])
def test_segmented_radix_sort_vs_per_segment_radix_sort(backend):
    """One segmented pass sequence == radix_sort on each segment slice."""
    keys = _keys(800, seed=10, hi=2**32)
    starts = [0, 123, 456, 456]
    ends = starts[1:] + [800]
    ks, _ = segmented_radix_sort(keys, starts, radix_bits=8, tile=256, backend=backend)
    for a, e in zip(starts, ends):
        ind, _ = radix_sort(keys[a:e], radix_bits=8, tile=256, backend=backend)
        np.testing.assert_array_equal(np.asarray(ks[a:e]), np.asarray(ind))


@pytest.mark.parametrize("backend", ["vmap", "pallas-interpret"])
def test_multisplit_all_shards_matches_global_oracle(backend):
    """The device-level local stage as ONE batched plan: global result ==
    stable multisplit of the concatenated shards."""
    d, n = 4, 600
    bf = delta_buckets(16, 2**30)
    keys = _keys(d * n, seed=12).reshape(d, n)
    vals = jnp.arange(d * n, dtype=jnp.int32).reshape(d, n)
    out = multisplit_all_shards(keys, bf, vals, tile=256, backend=backend)
    ref = multisplit_ref(keys.reshape(-1), bf, vals.reshape(-1))
    np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    np.testing.assert_array_equal(np.asarray(out.values), np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(out.bucket_counts), np.asarray(ref.bucket_counts))
    np.testing.assert_array_equal(np.asarray(out.bucket_starts), np.asarray(ref.bucket_starts))
    np.testing.assert_array_equal(np.asarray(out.permutation), np.asarray(ref.permutation))


def test_multisplit_all_shards_local_stage_is_one_batched_launch(monkeypatch):
    from repro.kernels import ops as kops

    post = _count_calls(monkeypatch, kops, "spec_fused_postscan_reorder")
    keys = _keys(4 * 512, seed=13).reshape(4, 512)
    # m=4 sits below PACKED_MIN_BUCKETS=8 (ISSUE 6), keeping the dense
    # entry point spied on above as the dispatched one
    bf = delta_buckets(4, 2**30)
    multisplit_all_shards(keys, bf, tile=256, backend="pallas-interpret")
    assert len(post) == 1                         # 4 shards, ONE local-stage launch


def test_moe_segmented_ranks_match_per_segment():
    """Token routing as ONE segmented multisplit call: per-request ranks and
    per-request expert loads equal independent per-request routing."""
    rng = np.random.RandomState(14)
    ids = jnp.asarray(rng.randint(0, 8, 4096, dtype=np.int32))
    starts = [0, 1024, 1024, 3000]
    ends = starts[1:] + [4096]
    r_seg, c_seg = moe._ranks_multisplit(ids, 8, segment_starts=starts)
    assert c_seg.shape == (4, 8)
    for i, (a, e) in enumerate(zip(starts, ends)):
        r_i, c_i = moe._ranks_multisplit(ids[a:e], 8)
        np.testing.assert_array_equal(np.asarray(r_seg[a:e]), np.asarray(r_i))
        np.testing.assert_array_equal(np.asarray(c_seg[i]), np.asarray(c_i))
    # the sort oracle agrees segment-by-segment too
    for a, e in zip(starts, ends):
        r_srt, _ = moe._ranks_sort(ids[a:e], 8)
        np.testing.assert_array_equal(np.asarray(r_seg[a:e]), np.asarray(r_srt))


def test_moe_route_tokens_segmented_slots():
    """Kept slots are unique, capacity-bounded and stable per (request,
    expert); dropped tokens are exactly the over-capacity tail."""
    rng = np.random.RandomState(15)
    e, cap = 4, 8
    ids = jnp.asarray(rng.randint(0, e, 400, dtype=np.int32))
    starts = [0, 100, 100, 280]
    slot, keep, counts = moe.route_tokens_segmented(ids, starts, e, cap)
    slot_np, keep_np = np.asarray(slot), np.asarray(keep)
    kept = slot_np[keep_np]
    assert len(set(kept.tolist())) == kept.size          # unique dispatch slots
    assert (slot_np[~keep_np] == len(starts) * e * cap).all()
    # per (segment, expert): kept count == min(load, cap)
    counts_np = np.asarray(counts)
    ends = starts[1:] + [400]
    ids_np = np.asarray(ids)
    for i, (a, b) in enumerate(zip(starts, ends)):
        for ex in range(e):
            load = int((ids_np[a:b] == ex).sum())
            assert counts_np[i, ex] == load
            in_block = (kept // cap == i * e + ex).sum()
            assert in_block == min(load, cap)


def test_moe_block_unchanged_by_plan_routing():
    """The flat routing rewrite (hand-rolled pipeline -> one plan call) must
    not change moe_block outputs vs the stable-sort oracle."""
    import dataclasses
    import jax
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.parallel.sharding import init_params

    cfg = ModelConfig(
        name="t", family="moe", n_layers=2, d_model=32, n_heads=4, n_kv=4,
        d_ff=64, vocab=64, dtype="float32",
        moe=MoEConfig(num_experts=8, top_k=2, dispatch="multisplit", capacity_factor=1.0),
    )
    params = init_params(moe.moe_decl(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32), jnp.float32)
    y_ms, aux_ms = moe.moe_block(params, x, cfg)
    y_srt, aux_srt = moe.moe_block(
        params, x, dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch="sort"))
    )
    np.testing.assert_array_equal(np.asarray(y_ms), np.asarray(y_srt))
    assert float(aux_ms.drop_fraction) == float(aux_srt.drop_fraction)
