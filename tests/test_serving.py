"""Serving subsystem acceptance (ISSUE 9): one segmented plan launch per
step, warm-plan reuse, fault retry/requeue/shed robustness, admission
behavior (deadline, caps, bucketing order, windowed planning), exact
percentiles, and the zero-length-segment regressions (S1)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import ops
from repro.core.identifiers import delta_buckets
from repro.core.multisplit import segmented_multisplit as core_segmented
from repro.models import moe
from repro.runtime.supervisor import FaultInjector
from repro.serving import (
    ServerLoop,
    ServingConfig,
    open_loop,
    percentiles,
    poisson_arrivals,
    synthetic_requests,
)

BACKENDS = ["reference", "vmap", "pallas-interpret"]

E = 4  # experts in the small test config


def _cfg(**kw) -> ServingConfig:
    base = dict(
        num_experts=E,
        capacity=8,
        max_batch_requests=8,
        max_batch_tokens=64,
        max_wait=0.0,          # deadline always expired: step fires when polled
        max_queue_depth=64,
    )
    base.update(kw)
    return ServingConfig(**base)


def _reqs(lengths, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, E, size=n).astype(np.int32) for n in lengths]


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class AlwaysFail:
    def check(self, step):
        raise RuntimeError("boom")


# ---------------------------------------------------------------------------
# Tentpole: k concurrent requests -> ONE segmented routing launch per step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_one_step_is_one_segmented_routing_call(backend, monkeypatch):
    """The coalescing claim, counter-tested: a step over k requests makes
    exactly ONE route_tokens_segmented call (the one segmented plan launch),
    on every segment backend."""
    loop = ServerLoop(_cfg(backend=backend))
    loop._jit_step = loop._step_fn    # eager, so the spy fires per call
    calls = []
    orig = moe.route_tokens_segmented

    def spy(ids, starts, *a, **k):
        calls.append((int(ids.shape[0]), int(np.asarray(starts).shape[0])))
        return orig(ids, starts, *a, **k)

    monkeypatch.setattr(moe, "route_tokens_segmented", spy)
    for r in _reqs([3, 5, 0, 7, 2]):       # ragged + one empty request
        assert loop.submit(r)
    rep = loop.step(force=True)
    loop.flush()
    assert rep["requests"] == 5 and rep["tokens"] == 17
    assert len(calls) == 1                 # 5 requests, ONE segmented launch
    n_pad, s_pad = calls[0]
    assert n_pad == rep["tokens_padded"] and s_pad == loop._s_pad
    s = loop.metrics_summary()
    assert s["completed"] == 5 and s["dropped_by_bug"] == 0


def test_pack_pads_with_last_expert_into_pad_segment():
    loop = ServerLoop(_cfg())
    reqs = _reqs([3, 0, 5])
    batch = []
    for r in reqs:
        loop.submit(r)
    batch = loop.queue.snapshot()
    ids, starts, n_tok = loop._pack(batch)
    assert n_tok == 8
    np.testing.assert_array_equal(ids[:8], np.concatenate([reqs[0], reqs[2]]))
    assert (ids[8:] == E - 1).all()        # pad tokens carry the last expert
    # starts: real cumsum then every remaining segment pinned at n_tok, so
    # pad tokens land in the trailing synthetic segment only
    assert starts.shape == (loop._s_pad,)
    np.testing.assert_array_equal(starts[:4], [0, 3, 3, 8])
    assert (starts[4:] == 8).all()


def test_warm_plan_reuse_zero_retraces():
    """Second same-shape-class step must not retrace the step function."""
    traces = []

    def step_fn(ids, starts):
        traces.append((ids.shape, starts.shape))
        return jnp.sum(ids) + jnp.sum(starts)

    loop = ServerLoop(_cfg(), step_fn=step_fn)
    for r in _reqs([4, 4]):
        loop.submit(r)
    loop.step(force=True)
    loop.flush()
    assert len(traces) == 1
    for r in _reqs([2, 3, 5], seed=1):     # different raggedness, same class
        loop.submit(r)
    loop.step(force=True)
    loop.flush()
    assert len(traces) == 1                # zero new traces
    assert loop.metrics_summary()["completed"] == 2 + 3


def test_routing_op_shared_across_loops():
    a = ServerLoop(_cfg())
    b = ServerLoop(_cfg())
    assert a._jit_step is b._jit_step      # lru-cached per (E, cap, backend)
    c = ServerLoop(_cfg(capacity=16))
    assert c._jit_step is not a._jit_step


# ---------------------------------------------------------------------------
# Robustness: in-step retry, requeue, bounded failure, load shedding
# ---------------------------------------------------------------------------

def test_fault_transient_retries_in_step():
    loop = ServerLoop(_cfg(), fault_injector=FaultInjector(fail_at={0: 1}))
    for r in _reqs([2, 3, 4]):
        loop.submit(r)
    loop.step(force=True)
    loop.flush()
    s = loop.metrics_summary()
    assert s["completed"] == 3 and s["failed"] == 0 and s["requeued"] == 0
    assert s["retries"] == 1 and s["dropped_by_bug"] == 0
    rec = loop.metrics.step_records[0]
    assert rec.ok and rec.attempts == 2


def test_fault_exhausts_attempts_requeues_then_succeeds():
    """A step that fails max_step_attempts times requeues its batch at the
    queue head; the next step completes it. Nothing is lost."""
    loop = ServerLoop(
        _cfg(max_step_attempts=3),
        fault_injector=FaultInjector(fail_at={0: 3}),
    )
    for r in _reqs([2, 3, 4]):
        loop.submit(r)
    s = loop.drain()
    assert s["completed"] == 3 and s["failed"] == 0
    assert s["requeued"] == 3 and s["retries"] == 2
    assert s["dropped_by_bug"] == 0 and s["queued"] == 0
    # FIFO order survived the requeue
    assert [rid for rid, _ in loop.completed] == [0, 1, 2]
    recs = loop.metrics.step_records
    assert [r.ok for r in recs] == [False, True]


def test_fault_persistent_fails_requests_counted():
    """Requests over their requeue budget fail (counted, deliberate) —
    drain terminates and conservation still holds."""
    loop = ServerLoop(
        _cfg(max_step_attempts=1, max_requeues=1),
        fault_injector=AlwaysFail(),
    )
    for r in _reqs([2, 3, 4, 5]):
        loop.submit(r)
    s = loop.drain()
    assert s["completed"] == 0 and s["failed"] == 4
    assert s["dropped_by_bug"] == 0 and s["queued"] == 0


def test_load_shed_on_queue_bound():
    loop = ServerLoop(_cfg(max_queue_depth=4))
    oks = [loop.submit(r) for r in _reqs([1] * 6)]
    assert oks == [True] * 4 + [False] * 2
    s = loop.drain()
    assert s["shed"] == 2 and s["completed"] == 4
    assert s["dropped_by_bug"] == 0


def test_load_shed_oversized_request():
    loop = ServerLoop(_cfg(max_batch_tokens=16))
    assert not loop.submit(np.zeros(17, np.int32))  # can never fit a batch
    s = loop.metrics_summary()
    assert s["shed"] == 1 and loop.queue.depth == 0


def test_fault_injector_rate_mode():
    fi = FaultInjector(rate=0.5, seed=0)
    hits = 0
    for i in range(200):
        try:
            fi.check(i)
        except RuntimeError:
            hits += 1
    assert hits == fi.injected and 50 < hits < 150
    with pytest.raises(ValueError):
        FaultInjector(rate=1.0)


# ---------------------------------------------------------------------------
# Admission: deadline, caps, bucketing order, windowed plan
# ---------------------------------------------------------------------------

def test_deadline_flush_fires_step():
    clk = FakeClock()
    loop = ServerLoop(_cfg(max_wait=0.05), clock=clk)
    loop.submit(np.zeros(3, np.int32))
    assert loop.step() is None             # underfull + deadline not expired
    clk.t += 0.06
    assert loop.step() is not None         # oldest waited past max_wait
    loop.flush()
    assert loop.metrics_summary()["completed"] == 1
    assert loop.metrics.empty_steps == 1


def test_full_batch_fires_without_deadline():
    clk = FakeClock()
    loop = ServerLoop(_cfg(max_wait=10.0, max_batch_requests=4), clock=clk)
    for r in _reqs([1, 1, 1]):
        loop.submit(r)
    assert loop.step() is None
    loop.submit(np.zeros(1, np.int32))     # request cap reached
    assert loop.step()["requests"] == 4


def test_token_cap_splits_batches():
    loop = ServerLoop(_cfg(max_batch_tokens=64, max_wait=10.0))
    for r in _reqs([30, 30, 30]):
        loop.submit(r)
    s = loop.drain()
    assert s["completed"] == 3
    sizes = [r.requests for r in loop.metrics.step_records]
    assert sizes == [2, 1]                 # 60 tokens, then the deferred 30
    assert all(r.tokens <= 64 for r in loop.metrics.step_records)


def test_bucketing_orders_by_length_class_oldest_first():
    """Admission order groups by RangeSpec length class, FIFO within a
    class, and the OLDEST request's class leads (no starvation)."""
    loop = ServerLoop(_cfg(length_splitters=(4, 16), max_wait=10.0))
    for r in _reqs([20, 2, 2, 20, 2]):
        loop.submit(r)
    loop.step(force=True)
    loop.flush()
    assert loop.metrics_summary()["completed"] == 5
    assert [rid for rid, _ in loop.completed] == [0, 3, 1, 2, 4]


def test_windowed_plan_pops_queue_once():
    """One admit carves the whole lookahead window: later steps pop the
    pending plan without touching the queue."""
    loop = ServerLoop(_cfg(max_batch_requests=2, max_batch_tokens=1000,
                           lookahead_batches=2))
    for r in _reqs([1] * 5):
        loop.submit(r)
    assert loop.step(force=True)["requests"] == 2
    assert loop.policy.pending() == 2      # second window batch, pre-carved
    assert loop.queue.depth == 1           # only the out-of-window request
    assert loop.step(force=True)["requests"] == 2
    assert loop.policy.pending() == 0
    loop.drain()
    assert loop.metrics_summary()["completed"] == 5


def test_trailing_underfull_remainder_requeued():
    """The window's trailing underfull batch goes back to the queue head to
    be rebatched densely with the next window, not shipped sparse."""
    loop = ServerLoop(_cfg(max_batch_requests=2, max_batch_tokens=1000,
                           lookahead_batches=2))
    for r in _reqs([1] * 3):
        loop.submit(r)
    assert loop.step(force=True)["requests"] == 2
    assert loop.policy.pending() == 0      # [r2] deferred, NOT planned
    assert loop.queue.depth == 1
    assert [q.rid for q in loop.queue.snapshot()] == [2]


def test_invalidate_returns_plan_to_queue_head_in_order():
    loop = ServerLoop(_cfg(max_batch_requests=2, max_batch_tokens=1000,
                           lookahead_batches=2))
    for r in _reqs([1] * 5):
        loop.submit(r)
    loop.step(force=True)                  # plan now holds [r2, r3]
    loop.flush()
    assert loop.policy.pending() == 2
    loop.policy.invalidate(loop.queue)
    assert loop.policy.pending() == 0
    assert [q.rid for q in loop.queue.snapshot()] == [2, 3, 4]


# ---------------------------------------------------------------------------
# Percentiles (S2): exact nearest-rank, pinned to numpy's inverted_cdf
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 10, 100, 997])
def test_percentiles_match_numpy_inverted_cdf(n):
    xs = np.random.RandomState(n).uniform(0, 1e3, size=n)
    ps = (1.0, 25.0, 50.0, 95.0, 99.0, 99.9, 100.0)
    got = percentiles(xs.tolist(), ps)
    want = np.percentile(xs, ps, method="inverted_cdf")
    for p, w in zip(ps, want):
        assert got[p] == w, (n, p)
        assert got[p] in xs                # an OBSERVED sample, never a blend


def test_percentiles_edges():
    assert percentiles([5.0], (0.0,))[0.0] == 5.0
    assert all(np.isnan(v) for v in percentiles([]).values())
    with pytest.raises(ValueError):
        percentiles([1.0], (101.0,))


def test_percentiles_reexported_from_benchmarks_common():
    from benchmarks.common import percentiles as bench_percentiles

    assert bench_percentiles is percentiles


# ---------------------------------------------------------------------------
# Open loop + engine edges
# ---------------------------------------------------------------------------

def test_open_loop_smoke_conserves_requests():
    cfg = _cfg(max_batch_tokens=256, max_queue_depth=512, max_wait=0.002)
    loop = ServerLoop(cfg)
    loop.prewarm()
    n = 300
    reqs = synthetic_requests(n, cfg.num_experts, seed=7)
    arrivals = poisson_arrivals(n, qps=20_000.0, seed=7)
    s = open_loop(loop, reqs, arrivals)
    assert s["submitted"] == n
    assert s["completed"] + s["shed"] == n and s["failed"] == 0
    assert s["dropped_by_bug"] == 0 and s["queued"] == 0
    assert np.isfinite(s["latency_p99_ms"]) and s["latency_p99_ms"] >= 0
    assert 0 < s["batch_token_occupancy"] <= 1.0


def test_empty_step_and_empty_drain():
    loop = ServerLoop(_cfg())
    assert loop.step(force=True) is None   # nothing queued: a no-op poll
    s = loop.drain()
    assert s["steps"] == 0 and s["dropped_by_bug"] == 0
    assert np.isnan(s["latency_p50_ms"])   # no latency distribution yet


def test_config_validation():
    with pytest.raises(ValueError):
        _cfg(token_pad_classes=(16,))      # largest class < max_batch_tokens
    with pytest.raises(ValueError):
        _cfg(max_step_attempts=0)
    with pytest.raises(ValueError):
        _cfg(lookahead_batches=0)
    with pytest.raises(ValueError):
        _cfg(length_splitters=(16, 4))


# ---------------------------------------------------------------------------
# S1 regressions: zero-length segments and the s == 0 step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_segmented_multisplit_zero_segments(backend):
    bf = delta_buckets(4, 2**10)
    keys = jnp.zeros((0,), jnp.uint32)
    for fn in (
        lambda: ops.segmented_multisplit(keys, bf, np.zeros((0,), np.int32),
                                         backend=backend),
        lambda: core_segmented(keys, bf, np.zeros((0,), np.int32),
                               backend=backend),
    ):
        out = fn()
        assert out.bucket_counts.shape == (0, 4)
        assert out.bucket_starts.shape == (0, 4)
        assert out.keys.shape == (0,)


def test_segmented_multisplit_zero_segments_rejects_nonempty_keys():
    bf = delta_buckets(4, 2**10)
    with pytest.raises(ValueError):
        ops.segmented_multisplit(
            jnp.zeros((8,), jnp.uint32), bf, np.zeros((0,), np.int32)
        )


@pytest.mark.parametrize("backend", BACKENDS)
def test_route_tokens_segmented_zero_requests(backend):
    slot, keep, counts = moe.route_tokens_segmented(
        jnp.zeros((0,), jnp.int32), np.zeros((0,), np.int32), E, 8,
        backend=backend,
    )
    assert slot.shape == (0,) and keep.shape == (0,)
    assert counts.shape == (0, E)


@pytest.mark.parametrize("backend", BACKENDS)
def test_route_tokens_segmented_zero_length_segments(backend):
    """Leading / interior / trailing empty segments: all-zero count rows,
    and the non-empty segments bitwise match their independent routing."""
    rng = np.random.RandomState(21)
    ids = jnp.asarray(rng.randint(0, E, 40, dtype=np.int32))
    starts = [0, 0, 10, 10, 10, 40]        # segs 0,2,3,5 are empty
    slot, keep, counts = moe.route_tokens_segmented(
        ids, starts, E, 8, backend=backend
    )
    counts_np = np.asarray(counts)
    assert counts_np.shape == (6, E)
    for empty_seg in (0, 2, 3, 5):
        assert (counts_np[empty_seg] == 0).all()
    ends = starts[1:] + [40]
    for i, (a, b) in enumerate(zip(starts, ends)):
        for ex in range(E):
            assert counts_np[i, ex] == int((np.asarray(ids[a:b]) == ex).sum())
    assert bool(np.asarray(keep)[: 0].all())  # vacuous on empties, no crash


# ---------------------------------------------------------------------------
# ISSUE 10 (S6): degradation + runtime-verification counters in serving
# ---------------------------------------------------------------------------

class AlwaysKernelFault:
    """Raises with a RESOURCE marker the resilience classifier recognizes —
    unlike AlwaysFail's generic 'boom', this is a persistent KERNEL failure
    and must degrade to the reference rung instead of requeueing."""

    def check(self, step):
        raise RuntimeError("RESOURCE_EXHAUSTED: out of memory in VMEM scratch")


@pytest.fixture
def rz_clean():
    from repro.runtime import resilience as rz

    rz.reset_stats()
    rz.set_verify(None)
    rz.set_strict(None)
    rz.set_fault_injector(None)
    yield rz
    rz.reset_stats()
    rz.set_verify(None)
    rz.set_strict(None)
    rz.set_fault_injector(None)


def test_metrics_summary_has_resilience_counters(rz_clean):
    loop = ServerLoop(_cfg())
    for r in _reqs([2, 3]):
        loop.submit(r)
    s = loop.drain()
    assert s["degradations"] == 0 and s["verify_mismatches"] == 0
    assert s["completed"] == 2 and s["dropped_by_bug"] == 0


def test_persistent_kernel_fault_degrades_to_reference(rz_clean):
    """Every launch hits a persistent resource fault: without the §17
    ladder all requests would exhaust their requeue budget and FAIL; with
    it, each step re-runs on the reference backend and completes."""
    loop = ServerLoop(
        _cfg(max_step_attempts=1, max_requeues=0),
        fault_injector=AlwaysKernelFault(),
    )
    for r in _reqs([2, 3, 4, 5]):
        loop.submit(r)
    s = loop.drain()
    assert s["completed"] == 4 and s["failed"] == 0
    assert s["degradations"] >= 1 and s["dropped_by_bug"] == 0
    assert rz_clean.stats()["degradations"] >= 1


def test_degrade_respects_strict_mode(rz_clean):
    """REPRO_STRICT disables the serving fallback too: the pre-§17
    requeue-then-fail accounting returns."""
    rz_clean.set_strict(True)
    loop = ServerLoop(
        _cfg(max_step_attempts=1, max_requeues=0),
        fault_injector=AlwaysKernelFault(),
    )
    for r in _reqs([2, 3]):
        loop.submit(r)
    s = loop.drain()
    assert s["completed"] == 0 and s["failed"] == 2
    assert s["degradations"] == 0 and s["dropped_by_bug"] == 0


def test_verify_mismatch_counted_and_healed_by_reference(rz_clean):
    """A lying step function (tampered routing counts) is caught by the
    sampled REPRO_VERIFY check; the step re-runs on reference and the
    mismatch is counted in the summary + the structured repro report."""
    rz_clean.set_verify(2)
    loop = ServerLoop(_cfg(verify_sample_rate=1.0))
    real = loop._jit_step

    def lying(ids, starts):
        slot, keep, counts = real(ids, starts)
        bad = np.asarray(counts).copy()
        bad[0, 0] += 1                       # breaks token conservation
        return slot, keep, jnp.asarray(bad)

    loop._jit_step = lying
    for r in _reqs([2, 3, 4]):
        loop.submit(r)
    s = loop.drain()
    assert s["completed"] == 3 and s["failed"] == 0
    assert s["verify_mismatches"] >= 1 and s["degradations"] >= 1
    assert s["dropped_by_bug"] == 0
    report = rz_clean.last_report()
    assert report is not None and report["spec"] == "route_tokens_segmented"
    assert rz_clean.stats()["verify_mismatches"] == s["verify_mismatches"]


def test_verify_sample_rate_zero_never_checks(rz_clean):
    rz_clean.set_verify(2)
    loop = ServerLoop(_cfg(verify_sample_rate=0.0))
    for r in _reqs([2, 3]):
        loop.submit(r)
    s = loop.drain()
    assert s["completed"] == 2 and s["verify_mismatches"] == 0
    assert rz_clean.stats()["verify_checks"] == 0


def test_verify_sample_rate_validation():
    with pytest.raises(ValueError, match="verify_sample_rate"):
        _cfg(verify_sample_rate=1.5)
