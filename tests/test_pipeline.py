"""The stage-graph pipeline package (ISSUE 3): backend registry, partial
pipelines (counts_only / positions_only), the chained RadixPipeline
(pad/tile exactly once per sort), and the repro.core.plan compat shim."""

import importlib
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import pipeline as pl
from repro.core.identifiers import delta_buckets
from repro.core.multisplit import (
    batched_multisplit,
    multisplit,
    multisplit_ref,
    segmented_multisplit,
)
from repro.core.pipeline import RadixPipeline, get_backend, make_plan
from repro.core.sort import radix_sort, radix_sort_per_pass, segmented_radix_sort

BACKENDS = ["reference", "vmap", "pallas-interpret"]


def _keys(n, seed=0, hi=2**30):
    return jnp.asarray(np.random.RandomState(seed).randint(0, hi, size=n, dtype=np.uint32))


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

def test_registry_knows_all_four_backends():
    names = pl.backend_names()
    assert names == ("reference", "vmap", "pallas-interpret", "pallas")
    assert pl.BACKENDS == names
    for b in pl.available_backends():
        assert b.description


def test_registry_capability_flags():
    assert not get_backend("reference").tiled
    assert get_backend("vmap").tiled and not get_backend("vmap").uses_kernels
    for name in ("pallas-interpret", "pallas"):
        b = get_backend(name)
        assert b.uses_kernels and b.fuses_radix and b.key_itemsize == 4
    # 'pallas' is COMPILED-when-available: interpret resolves dynamically
    # from Backend.compiled × TPU presence × REPRO_INTERPRET (DESIGN.md §15).
    assert not get_backend("pallas-interpret").compiled
    assert get_backend("pallas").compiled
    from repro.kernels import ops as kops
    assert get_backend("pallas-interpret").stages.interpret is True
    assert get_backend("pallas").stages.interpret == kops.resolve_interpret(True)


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(ValueError):
        get_backend("cuda")
    with pytest.raises(ValueError):
        pl.make_plan(100, 4, backend="cuda")
    with pytest.raises(ValueError):
        pl.register_backend(pl.Backend(name="vmap", description="dup"))


def test_registry_extension_is_one_call():
    """A new execution target is one register_backend call: plans resolve and
    run through it with zero changes anywhere else."""
    pl.register_backend(pl.Backend(
        name="vmap-twin", description="test-only clone", stages=pl.VmapStages()
    ))
    try:
        bf = delta_buckets(8, 2**30)
        keys = _keys(500, seed=1)
        out = make_plan(500, 8, backend="vmap-twin", bucket_fn=bf, tile=128)(keys)
        ref = multisplit_ref(keys, bf)
        np.testing.assert_array_equal(np.asarray(out.keys), np.asarray(ref.keys))
    finally:
        del pl.registry._REGISTRY["vmap-twin"]


# ---------------------------------------------------------------------------
# PipelineSpec: modes, validation, stage graph
# ---------------------------------------------------------------------------

def test_mode_validation():
    with pytest.raises(ValueError):
        make_plan(100, 4, mode="sideways")
    with pytest.raises(ValueError):                  # partial modes are key-only
        make_plan(100, 4, mode="counts_only", key_value=True)
    p = make_plan(100, 4, bucket_fn=delta_buckets(4), mode="counts_only")
    with pytest.raises(ValueError):                  # resolved key-only
        p(_keys(100), jnp.arange(100))


def test_stage_graph_per_mode():
    # m=4 < PACKED_MIN_BUCKETS keeps the stage names family-tag-free
    bf = delta_buckets(4)
    full = make_plan(1024, 4, method="bms", backend="vmap", bucket_fn=bf)
    co = make_plan(1024, 4, method="bms", backend="vmap", bucket_fn=bf,
                   mode="counts_only")
    po = make_plan(1024, 4, method="bms", backend="pallas-interpret",
                   bucket_fn=bf, mode="positions_only")
    assert full.stages() == (
        "prescan:vmap", "scan:global", "postscan:fused-reorder-vmap",
        "scatter:bucket-major",
    )
    assert co.stages() == ("prescan:vmap", "reduce:counts")
    assert po.stages() == (           # fusable spec on a kernel backend (PR-4)
        "prescan:fused-label-kernel", "scan:global",
        "postscan:fused-label-positions-kernel",
    )
    assert [s.name for s in co.stage_graph()] == ["prescan", "reduce"]
    assert co.stage_graph()[0].impl == "vmap"
    seg = make_plan(1024, 4, bucket_fn=bf, segments=4, mode="counts_only")
    assert seg.stage_graph()[0].name == "layout"


def test_counts_only_empty_and_layout_shapes():
    bf = delta_buckets(4)
    for backend in BACKENDS:
        flat = make_plan(0, 4, backend=backend, bucket_fn=bf, mode="counts_only")(_keys(0))
        assert flat.keys is None and flat.permutation is None
        np.testing.assert_array_equal(np.asarray(flat.bucket_counts), np.zeros(4))
        bt = make_plan(0, 4, backend=backend, bucket_fn=bf, batch=3,
                       mode="counts_only")(_keys(0).reshape(3, 0))
        assert bt.bucket_counts.shape == (3, 4)


def test_partial_modes_non_32bit_keys_on_kernel_backend():
    """Non-fused partial modes never feed keys to a kernel (only int32 ids),
    so non-32-bit key dtypes stay usable — the histogram consumer's float
    path and any positions-only bucketing over narrow keys. The full reorder
    (keys DO enter the kernel) still enforces the 32-bit-lane restriction."""
    from repro.core.identifiers import from_fn

    keys = jnp.asarray(np.random.RandomState(0).randint(0, 8, 5000, dtype=np.uint16))
    bf = from_fn(lambda u: u.astype(jnp.int32), 8, name="u16-identity")
    out = multisplit(keys, bf, tile=256, backend="pallas-interpret", mode="counts_only")
    np.testing.assert_array_equal(
        np.asarray(out.bucket_counts), np.bincount(np.asarray(keys), minlength=8)
    )
    po = multisplit(keys, bf, tile=256, backend="pallas-interpret", mode="positions_only")
    ref = multisplit_ref(keys, bf)
    np.testing.assert_array_equal(np.asarray(po.permutation), np.asarray(ref.permutation))
    with pytest.raises(ValueError):                  # the full reorder still checks
        multisplit(keys, bf, tile=256, backend="pallas-interpret")


# ---------------------------------------------------------------------------
# RadixPipeline: chained passes, bitwise identity, pad/tile exactly once
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", ["dms", "bms"])
@pytest.mark.parametrize("key_value", [False, True])
def test_chained_radix_bitwise_matches_per_pass(backend, method, key_value):
    """THE acceptance criterion: radix_sort (chained RadixPipeline) is
    bitwise identical to the PR-2 per-pass execution on every backend."""
    rng = np.random.RandomState(7)
    keys = jnp.asarray(rng.randint(0, 2**32, 2500 + 13, dtype=np.uint32))
    vals = jnp.arange(keys.shape[0], dtype=jnp.int32) if key_value else None
    ks, vs = radix_sort(keys, vals, radix_bits=8, method=method, backend=backend, tile=512)
    ks2, vs2 = radix_sort_per_pass(
        keys, vals, radix_bits=8, method=method, backend=backend, tile=512
    )
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(ks2))
    order = np.argsort(np.asarray(keys), kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(keys)[order])
    if key_value:
        np.testing.assert_array_equal(np.asarray(vs), np.asarray(vs2))
        np.testing.assert_array_equal(np.asarray(vs), np.asarray(vals)[order])


@pytest.mark.parametrize("backend", BACKENDS)
def test_chained_batched_radix_matches_per_pass(backend):
    rng = np.random.RandomState(3)
    keys = jnp.asarray(rng.randint(0, 2**16, (5, 700), dtype=np.uint32))
    ks, _ = radix_sort(keys, radix_bits=4, key_bits=16, backend=backend, tile=128)
    ks2, _ = radix_sort_per_pass(keys, radix_bits=4, key_bits=16, backend=backend, tile=128)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(ks2))
    np.testing.assert_array_equal(np.asarray(ks), np.sort(np.asarray(keys), axis=1))


@pytest.mark.parametrize("backend", BACKENDS)
def test_chained_segmented_radix_matches_per_pass(backend):
    rng = np.random.RandomState(5)
    keys = jnp.asarray(rng.randint(0, 2**16, 900, dtype=np.uint32))
    starts = [0, 0, 300, 650]                        # empty first segment
    ks, _ = segmented_radix_sort(
        keys, starts, radix_bits=4, key_bits=16, backend=backend, tile=128
    )
    ks2, _ = radix_sort_per_pass(
        keys, radix_bits=4, key_bits=16, backend=backend, tile=128,
        segment_starts=starts,
    )
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(ks2))
    for a, e in zip(starts, starts[1:] + [900]):
        np.testing.assert_array_equal(
            np.asarray(ks[a:e]), np.sort(np.asarray(keys[a:e]))
        )


def _count_padding(monkeypatch):
    from repro.core.pipeline import stages as st

    calls = {"pad_to_tiles": 0, "pad_rows": 0}
    orig_pt, orig_pr = st.pad_to_tiles, st.pad_rows

    def count_pt(x, tile, fill):
        calls["pad_to_tiles"] += 1
        return orig_pt(x, tile, fill)

    def count_pr(x, n_row, fill):
        calls["pad_rows"] += 1
        return orig_pr(x, n_row, fill)

    monkeypatch.setattr(st, "pad_to_tiles", count_pt)
    monkeypatch.setattr(st, "pad_rows", count_pr)
    return calls


@pytest.mark.parametrize("backend", ["vmap", "pallas-interpret"])
def test_radix_pipeline_pads_and_tiles_exactly_once(backend, monkeypatch):
    """Acceptance: the chained pipeline pads/tiles each operand ONCE per
    sort; the legacy per-pass path re-pads every pass."""
    calls = _count_padding(monkeypatch)
    rng = np.random.RandomState(0)
    keys = jnp.asarray(rng.randint(0, 2**32, 3000, dtype=np.uint32))
    vals = jnp.arange(3000, dtype=jnp.int32)

    ks, vs = radix_sort(keys, vals, radix_bits=8, backend=backend, tile=512)
    assert calls["pad_to_tiles"] == 2                # keys once + values once
    chained = calls["pad_to_tiles"]

    radix_sort_per_pass(keys, vals, radix_bits=8, backend=backend, tile=512)
    legacy = calls["pad_to_tiles"] - chained
    n_pass = 4
    # per pass: keys + values (+ host-side ids on non-fusing backends)
    assert legacy >= 2 * n_pass
    order = np.argsort(np.asarray(keys), kind="stable")
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(keys)[order])
    np.testing.assert_array_equal(np.asarray(vs), np.asarray(vals)[order])


def test_segmented_radix_pipeline_pads_once(monkeypatch):
    calls = _count_padding(monkeypatch)
    keys = _keys(900, seed=2, hi=2**16)
    segmented_radix_sort(keys, [0, 300, 650], radix_bits=4, key_bits=16, tile=128)
    # keys once + the position-keyed segment-id buffer once (key-only sort)
    assert calls["pad_to_tiles"] == 2


def test_batched_radix_pipeline_pads_rows_once(monkeypatch):
    calls = _count_padding(monkeypatch)
    keys = _keys(4 * 700, seed=4, hi=2**16).reshape(4, 700)
    radix_sort(keys, radix_bits=4, key_bits=16, tile=128)
    assert calls["pad_rows"] == 1 and calls["pad_to_tiles"] == 0


def test_radix_pipeline_resolves_tile_once():
    """All per-pass plans share ONE resolved tile (no per-pass re-resolution
    drift, even when the final pass has a narrower digit)."""
    rp = RadixPipeline(100_000, radix_bits=7, key_bits=32, backend="vmap")
    assert rp.n_passes == 5
    assert len({p.tile for p in rp.plans}) == 1
    assert rp.plans[-1].radix == (28, 4)             # 5th pass covers 4 bits


# ---------------------------------------------------------------------------
# repro.core.plan compat shim
# ---------------------------------------------------------------------------

def test_plan_shim_import_compat():
    """Old imports keep working, warning-free, and share state with the
    package (the tile cache is the SAME dict, not a copy)."""
    import repro.core.plan as plan_shim

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        plan_shim = importlib.reload(plan_shim)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert not dep, f"plan shim import raised {dep}"
    for sym in (
        "MultisplitPlan", "MultisplitResult", "make_plan", "make_radix_plan",
        "make_batched_plan", "make_segmented_plan", "make_segmented_radix_plan",
        "resolve_tile", "autotune_tile", "clear_tile_cache", "resolve_backend",
        "BACKENDS", "WMS_TILE", "BMS_TILE", "global_scan", "pad_to_tiles",
        "segment_ids_from_starts", "tile_local_offsets", "_TILE_CACHE",
        "_heuristic_tile", "_VMEM_BUDGET_BYTES", "_MIN_TILE",
    ):
        assert hasattr(plan_shim, sym), f"shim lost {sym}"
    from repro.core.pipeline import tiles

    assert plan_shim._TILE_CACHE is tiles._TILE_CACHE


def test_no_private_cross_module_reaches_in_consumers():
    """Acceptance: migrated consumers are grep-clean of private plan-layer
    reaches (the old ``ms._pad_to_tiles`` / ``HIST_TILE`` layering bug)."""
    import inspect

    from repro.core import distributed, histogram, sort
    from repro.data import pipeline as data_pipeline
    from repro.models import moe

    for mod in (histogram, sort, distributed, moe, data_pipeline):
        src = inspect.getsource(mod)
        assert "ms._pad_to_tiles" not in src, mod.__name__
        assert "HIST_TILE" not in src, mod.__name__
        assert "plan._" not in src, mod.__name__
        assert "pipeline._" not in src.replace("data_pipeline._", ""), mod.__name__


# ---------------------------------------------------------------------------
# Partial-pipeline consumers
# ---------------------------------------------------------------------------

def test_histogram_is_counts_only_pipeline(monkeypatch):
    """histogram() must not run scan/postscan/scatter: reorder and positions
    stage entry points stay untouched."""
    from repro.core.histogram import histogram_even
    from repro.core.pipeline.registry import KernelStages, VmapStages

    def boom(*a, **k):
        raise AssertionError("counts_only pipeline ran a post-prescan stage")

    for cls in (KernelStages, VmapStages):
        monkeypatch.setattr(cls, "positions", boom)
        monkeypatch.setattr(cls, "reorder", boom)
    keys = jnp.asarray(np.random.RandomState(1).uniform(0, 64, 9000).astype(np.float32))
    for use_pallas in (False, True):
        h = histogram_even(keys, 0.0, 64.0, 16, use_pallas=use_pallas)
        expect, _ = np.histogram(np.asarray(keys), bins=16, range=(0, 64))
        np.testing.assert_array_equal(np.asarray(h), expect)


def test_moe_expert_load_stats_counts_only():
    from repro.models.moe import expert_load_stats

    rng = np.random.RandomState(0)
    ids = jnp.asarray(rng.randint(0, 8, 1000, dtype=np.int32))
    counts, overflow = expert_load_stats(ids, 8, capacity=100)
    np.testing.assert_array_equal(
        np.asarray(counts), np.bincount(np.asarray(ids), minlength=8)
    )
    expect_drop = np.maximum(np.bincount(np.asarray(ids), minlength=8) - 100, 0).sum() / 1000
    assert abs(float(overflow) - expect_drop) < 1e-6
    # segmented: per-request load in one call
    starts = jnp.asarray([0, 400, 400], jnp.int32)
    seg_counts, _ = expert_load_stats(ids, 8, segment_starts=starts)
    assert seg_counts.shape == (3, 8)
    np.testing.assert_array_equal(
        np.asarray(seg_counts[0]), np.bincount(np.asarray(ids[:400]), minlength=8)
    )
    np.testing.assert_array_equal(np.asarray(seg_counts[1]), np.zeros(8))


def test_data_pipeline_bucket_orders_segmented():
    """batches_at buckets every step's lengths in ONE segmented launch and is
    bitwise identical to independent batch_at calls."""
    from repro.data import DataPipeline

    p = DataPipeline(vocab=256, seq_len=128, batch_per_host=2, seed=7)
    expect = [p.batch_at(5 + i) for i in range(3)]
    got = p.batches_at(5, 3)
    for e, g in zip(expect, got):
        np.testing.assert_array_equal(e["tokens"], g["tokens"])
        np.testing.assert_array_equal(e["labels"], g["labels"])
